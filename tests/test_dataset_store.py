"""Dataset store subsystem: streaming libsvm I/O, sharded mmap round-trips,
deterministic splits, column stats, the persisted fw_setup cache, and the
named-dataset registry (DESIGN.md §7).

The load-bearing guarantees:
  * text → store → mmap → HostCSR is **bit-for-bit** identical to the
    in-memory matrix (float64 values survive the %.17g text round trip);
  * the cached setup state replays exactly, so warm solves are the same
    state machine as cold ones (solver-level parity is pinned in
    tests/test_solvers.py).
"""
import io
import os

import numpy as np
import pytest

from repro.core.sparse.formats import HostCSR
from repro.data.sparse_io import iter_libsvm, write_libsvm
from repro.data.store import DatasetRef, DatasetStore
from repro.data.synthetic import make_sparse_classification


@pytest.fixture(scope="module")
def problem():
    X, y, _ = make_sparse_classification(n=140, d=520, nnz_per_row=9,
                                         informative=12, seed=5)
    return X, y


@pytest.fixture(scope="module")
def store(problem, tmp_path_factory):
    X, y = problem
    root = tmp_path_factory.mktemp("ds") / "store"
    # small shards + small chunks so sharding and chunk-splitting both fire
    return DatasetStore.from_arrays(str(root), X, y, rows_per_shard=33,
                                    chunk_rows=17)


# ---------------------------------------------------------------------------
# sparse_io
# ---------------------------------------------------------------------------


def test_libsvm_text_round_trip_bit_for_bit(problem):
    X, y = problem
    buf = io.StringIO()
    write_libsvm(buf, X, y)
    buf.seek(0)
    chunks = list(iter_libsvm(buf, chunk_rows=13))
    assert sum(c.n_rows for c in chunks) == X.shape[0]
    cols = np.concatenate([c.cols for c in chunks])
    vals = np.concatenate([c.vals for c in chunks])
    ys = np.concatenate([c.y for c in chunks])
    np.testing.assert_array_equal(cols, X.indices)
    np.testing.assert_array_equal(vals, X.data)  # %.17g is float64-exact
    np.testing.assert_array_equal(ys, y)


def test_libsvm_parser_tolerates_comments_qid_and_signs():
    text = ("# a comment line\n"
            "+1 qid:3 2:0.5 7:-1.25  # trailing comment\n"
            "\n"
            "-1 1:3\n")
    chunks = list(iter_libsvm(io.StringIO(text), chunk_rows=10))
    assert len(chunks) == 1
    c = chunks[0]
    np.testing.assert_array_equal(c.y, [1.0, 0.0])
    np.testing.assert_array_equal(c.cols, [1, 6, 0])   # 1-based -> 0-based
    np.testing.assert_array_equal(c.vals, [0.5, -1.25, 3.0])


def test_libsvm_zero_based_mode():
    c = next(iter_libsvm(io.StringIO("1 0:2.0 5:1.0\n"), zero_based=True))
    np.testing.assert_array_equal(c.cols, [0, 5])


# ---------------------------------------------------------------------------
# store: round trip, mmap views, manifest, stats
# ---------------------------------------------------------------------------


def test_store_round_trip_bit_for_bit(problem, store):
    X, y = problem
    Z = store.to_host_csr()
    np.testing.assert_array_equal(Z.indptr, X.indptr)
    np.testing.assert_array_equal(Z.indices, X.indices)
    np.testing.assert_array_equal(Z.data, X.data)
    np.testing.assert_array_equal(store.labels(), y)
    assert store.shape == X.shape and store.nnz == X.nnz


def test_store_full_libsvm_ingestion_path(problem, tmp_path):
    """text file → streaming parse → store → mmap equals the source matrix."""
    X, y = problem
    svm = tmp_path / "ds.svm"
    write_libsvm(str(svm), X, y)
    st = DatasetStore.write(str(tmp_path / "st"),
                            iter_libsvm(str(svm), chunk_rows=29),
                            n_cols=X.shape[1], rows_per_shard=50)
    Z = st.to_host_csr()
    np.testing.assert_array_equal(Z.data, X.data)
    np.testing.assert_array_equal(Z.indices, X.indices)
    np.testing.assert_array_equal(st.labels(), y)


def test_store_shards_are_mmap_views(store):
    assert store.n_shards > 1           # rows_per_shard=33 over 140 rows
    rows = 0
    for i in range(store.n_shards):
        sh = store.shard(i)
        assert isinstance(sh.data, np.memmap) or \
            isinstance(np.asarray(sh.data).base, np.memmap)
        assert sh.indptr[0] == 0
        rows += sh.shape[0]
        assert sh.shape[0] == store.manifest["shards"][i]["rows"]
        assert sh.nnz == store.manifest["shards"][i]["nnz"]
    assert rows == store.n


def test_store_manifest_and_content_hash(problem, store, tmp_path):
    X, y = problem
    m = store.manifest
    assert m["n"] == X.shape[0] and m["d"] == X.shape[1]
    assert m["nnz"] == X.nnz and len(m["shards"]) == store.n_shards
    # same data -> same hash, regardless of shard/chunk geometry
    st2 = DatasetStore.from_arrays(str(tmp_path / "again"), X, y,
                                   rows_per_shard=1000, chunk_rows=7)
    assert st2.content_hash == store.content_hash
    # a one-bit perturbation changes it
    Xp = HostCSR(X.indptr, X.indices, X.data.copy(), X.shape)
    Xp.data[0] += 1e-9
    st3 = DatasetStore.from_arrays(str(tmp_path / "pert"), Xp, y,
                                   rows_per_shard=1000)
    assert st3.content_hash != store.content_hash


def test_store_open_missing_and_reopen(store, tmp_path):
    with pytest.raises(FileNotFoundError):
        DatasetStore.open(str(tmp_path / "nope"))
    st = DatasetStore.open(store.root)
    assert st.content_hash == store.content_hash
    np.testing.assert_array_equal(st.labels(), store.labels())


def test_column_stats_match_direct_computation(problem, store):
    X, y = problem
    stats = store.col_stats()
    d = X.shape[1]
    np.testing.assert_array_equal(
        stats.df, np.bincount(X.indices, minlength=d))
    np.testing.assert_allclose(
        stats.norm_sq,
        np.bincount(X.indices, weights=X.data ** 2, minlength=d))
    np.testing.assert_allclose(
        stats.col_sum, np.bincount(X.indices, weights=X.data, minlength=d))
    y_rep = np.repeat(y, np.diff(X.indptr))
    np.testing.assert_allclose(
        stats.col_y_sum,
        np.bincount(X.indices, weights=X.data * y_rep, minlength=d))


# ---------------------------------------------------------------------------
# splits & row materialization
# ---------------------------------------------------------------------------


def test_split_deterministic_disjoint_and_salted(store):
    tr1, te1 = store.split(0.25, salt=0)
    tr2, te2 = store.split(0.25, salt=0)
    np.testing.assert_array_equal(tr1, tr2)
    np.testing.assert_array_equal(te1, te2)
    assert set(tr1).isdisjoint(te1)
    assert len(tr1) + len(te1) == store.n
    assert 0.05 < len(te1) / store.n < 0.5      # ≈ 0.25 at n=140
    _, te_salted = store.split(0.25, salt=1)
    assert not np.array_equal(te1, te_salted)


def test_take_matches_dense_slicing(problem, store):
    X, y = problem
    rows = np.array([0, 3, 34, 35, 100, 139])   # crosses shard boundaries
    Xs, ys = store.take(rows)
    np.testing.assert_array_equal(Xs.to_dense(), X.to_dense()[rows])
    np.testing.assert_array_equal(ys, y[rows])
    with pytest.raises(IndexError):
        store.take([store.n])


def test_take_preserves_caller_order(problem, store):
    """A shuffled (and repeating) row list comes back in that exact order."""
    X, y = problem
    rng = np.random.default_rng(3)
    rows = rng.permutation(store.n)[:25]
    rows = np.concatenate([rows, rows[:3]])     # duplicates allowed
    Xs, ys = store.take(rows)
    np.testing.assert_array_equal(Xs.to_dense(), X.to_dense()[rows])
    np.testing.assert_array_equal(ys, y[rows])


# ---------------------------------------------------------------------------
# solver setup cache & out-of-core setup
# ---------------------------------------------------------------------------


def test_setup_cache_persists_and_replays_bitwise(problem, store):
    import jax.numpy as jnp

    from repro.core.solvers.jax_sparse import fw_setup_jit
    X, y = problem
    prep = store.prepared()
    s1 = prep.setup_for(y, "logistic", True)
    path = store._setup_cache_path("logistic", True)
    assert os.path.exists(path)
    # a fresh open must hit the disk cache and replay identical bits
    st2 = DatasetStore.open(store.root)
    s2 = st2.prepared().setup_for(y, "logistic", True)
    for a, b in zip(s1, s2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and the cache content equals a direct fw_setup on the padded pair
    ref = fw_setup_jit(prep.pcsr, jnp.asarray(y, jnp.float32),
                       loss="logistic", interpret=True)
    for a, b in zip(s1, ref):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_padded_cache_replays_bitwise(store):
    """Warm opens mmap the persisted ELL lanes — identical to a cold build."""
    prep1 = store.prepared()
    assert os.path.exists(store._padded_meta_path())
    st2 = DatasetStore.open(store.root)
    prep2 = st2.prepared()
    for p1, p2 in ((prep1.pcsr, prep2.pcsr), (prep1.pcsc, prep2.pcsc)):
        np.testing.assert_array_equal(np.asarray(p1.indices),
                                      np.asarray(p2.indices))
        np.testing.assert_array_equal(np.asarray(p1.values),
                                      np.asarray(p2.values))
        np.testing.assert_array_equal(np.asarray(p1.nnz), np.asarray(p2.nnz))
        assert p1.shape == p2.shape


def test_setup_cache_ignores_foreign_labels(problem, store):
    X, y = problem
    prep = store.prepared()
    cached = prep.setup_for(y, "logistic", True)
    flipped = 1.0 - y
    fresh = prep.setup_for(flipped, "logistic", True)
    assert not np.array_equal(np.asarray(cached[2]), np.asarray(fresh[2]))


def test_setup_streamed_matches_kernel_setup(problem, store):
    import jax.numpy as jnp

    from repro.core.solvers.jax_sparse import fw_setup_jit
    X, y = problem
    v0, q0, a0 = store.setup_streamed("logistic")
    ref = fw_setup_jit(store.prepared().pcsr, jnp.asarray(y, jnp.float32),
                       loss="logistic", interpret=True)
    np.testing.assert_allclose(np.asarray(a0), np.asarray(ref[2]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(q0), np.asarray(ref[1]), atol=1e-6)
    assert float(np.abs(np.asarray(v0)).max()) == 0.0


# ---------------------------------------------------------------------------
# DatasetRef & registry
# ---------------------------------------------------------------------------


def test_dataset_ref_validation():
    with pytest.raises(ValueError, match="exactly one"):
        DatasetRef()
    with pytest.raises(ValueError, match="exactly one"):
        DatasetRef(name="a", path="b")
    with pytest.raises(ValueError, match="unknown split"):
        DatasetRef(name="a", split="validation")


def test_dataset_ref_split_resolution(problem, store):
    X, y = problem
    Xt, yt = DatasetRef(path=store.root, split="test", test_frac=0.3,
                        salt=2).resolve()
    _, te = store.split(0.3, salt=2)
    np.testing.assert_array_equal(Xt.to_dense(), X.to_dense()[te])
    np.testing.assert_array_equal(yt, y[te])
    src, y_all = DatasetRef(path=store.root).resolve()
    assert isinstance(src, DatasetStore)
    np.testing.assert_array_equal(y_all, y)


def test_registry_generates_then_caches(tmp_path):
    from repro.data.registry import (DatasetSpec, available_datasets, load,
                                     register_dataset)
    assert "rcv1_like" in available_datasets()
    register_dataset(DatasetSpec("tiny_test", n=60, d=120, nnz_per_row=5.0,
                                 informative=6, rows_per_shard=25))
    st1 = load("tiny_test", root=str(tmp_path))
    assert st1.n == 60 and st1.d == 120 and st1.n_shards == 3
    created = st1.manifest["created_unix"]
    st2 = load("tiny_test", root=str(tmp_path))   # cache hit: no rebuild
    assert st2.manifest["created_unix"] == created
    assert st2.content_hash == st1.content_hash
    # spec change invalidates via the fingerprint
    register_dataset(DatasetSpec("tiny_test", n=60, d=120, nnz_per_row=5.0,
                                 informative=6, rows_per_shard=25, seed=9))
    st3 = load("tiny_test", root=str(tmp_path))
    assert st3.content_hash != st1.content_hash
    with pytest.raises(ValueError, match="unknown dataset"):
        load("not_registered", root=str(tmp_path))


def test_fit_service_accepts_dataset_store(problem, store):
    """FitService(store) serves fits off the cached prepared dataset."""
    from repro.core.dp.accountant import PrivacyAccountant
    from repro.core.solvers import FWConfig, solve
    from repro.serve.fit_service import FitRequest, FitService
    X, y = problem
    cfg = FWConfig(backend="jax_sparse", lam=8.0, steps=12, queue="bsls",
                   epsilon=1.0, delta=1e-6)
    svc = FitService(store, accountants={
        "t0": PrivacyAccountant(epsilon=4.0, delta=1e-6, total_steps=200)})
    svc.submit(FitRequest(uid=0, tenant="t0", config=cfg))
    done = svc.run()
    assert done[0].status == "done"
    ref = solve(X, y, cfg)
    np.testing.assert_array_equal(np.asarray(done[0].result.coords),
                                  np.asarray(ref.coords))


def test_setup_streamed_matches_kernel_setup_label_coupled(problem, store):
    """huber is label-coupled: setup_streamed's q̄₀ = a + b·y affine path
    (exact for binary labels) must agree with the kernel fw_setup."""
    import jax.numpy as jnp

    from repro.core.solvers.jax_sparse import fw_setup_jit
    X, y = problem
    v0, q0, a0 = store.setup_streamed("huber")
    ref = fw_setup_jit(store.prepared().pcsr, jnp.asarray(y, jnp.float32),
                       loss="huber", interpret=True)
    np.testing.assert_allclose(np.asarray(a0), np.asarray(ref[2]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(q0), np.asarray(ref[1]), atol=1e-6)
    assert float(np.abs(np.asarray(v0)).max()) == 0.0
