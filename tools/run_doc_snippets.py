"""Execute every fenced ```python snippet in the given markdown files.

CI's docs job runs this over README.md and docs/API.md so the documented
code paths cannot silently rot: a snippet that raises fails the build.
Snippets within one file share a single namespace and run top-to-bottom,
so a later snippet may use names an earlier one defined (the README's
quickstart builds on itself this way).

Opt-out: put ``<!-- snippet: skip -->`` on the line directly above a fence
to exclude it (for illustrative fragments that are not runnable as-is,
e.g. shell transcripts typed as python or deliberately-failing examples).

Usage: PYTHONPATH=src python tools/run_doc_snippets.py FILE.md [FILE.md ...]
"""
from __future__ import annotations

import pathlib
import re
import sys

FENCE = re.compile(
    r"(?P<skip><!--\s*snippet:\s*skip\s*-->\s*\n)?"
    r"^```python[ \t]*\n(?P<body>.*?)^```",
    re.MULTILINE | re.DOTALL)


def snippets(text: str):
    """(index, body, skipped) for every python fence in ``text``."""
    for i, m in enumerate(FENCE.finditer(text)):
        yield i, m.group("body"), bool(m.group("skip"))


def run_file(path: pathlib.Path) -> int:
    """Execute ``path``'s snippets in one shared namespace; count failures."""
    ns = {"__name__": "__doc_snippet__", "__file__": str(path)}
    failures = 0
    for i, body, skipped in snippets(path.read_text()):
        label = f"{path}#snippet-{i}"
        if skipped:
            print(f"[docs] {label}: skipped (snippet: skip)")
            continue
        print(f"[docs] {label}: running ({len(body.splitlines())} lines)",
              flush=True)
        try:
            exec(compile(body, label, "exec"), ns)  # noqa: S102
        except Exception:  # noqa: BLE001
            import traceback
            traceback.print_exc()
            print(f"[docs] {label}: FAILED", flush=True)
            failures += 1
    return failures


def main(argv) -> int:
    if not argv:
        print(__doc__)
        return 2
    total, missing = 0, 0
    for name in argv:
        path = pathlib.Path(name)
        if not path.exists():
            print(f"[docs] {name}: no such file")
            missing += 1
            continue
        total += run_file(path)
    if total or missing:
        print(f"[docs] {total} snippet failure(s), {missing} missing file(s)")
        return 1
    print("[docs] all snippets ok")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
