"""NOTE: LM-scale serving scaffolding — not part of the DP-LASSO
reproduction (see README "Examples" and docs/API.md for the paper surface).

Enc-dec (seamless-m4t) serving: encoder prefill fills the cross-attention
K/V cache, then batched greedy decoding — speech-to-text-style inference.

    PYTHONPATH=src python examples/serve_encdec.py

Consistency check: the step-by-step decode must match the teacher-forced
parallel decoder (`lm_forward`) on the same frames + prefix.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models import encdec

cfg = smoke_config("seamless-m4t-medium")
params = encdec.lm_init(jax.random.PRNGKey(0), cfg)

B, S_ENC, MAX_DEC = 4, 24, 40
rng = np.random.default_rng(0)
frames = jnp.asarray(rng.normal(0, 1, (B, S_ENC, cfg.d_model)), cfg.jdtype)
bos = jnp.ones((B, 1), jnp.int32)

# ---- encoder prefill: one pass fills every decoder layer's cross K/V ------
t0 = time.time()
cache = encdec.lm_init_cache(cfg, B, MAX_DEC)
cache = jax.jit(lambda c, f: encdec.prefill_cross(params, c, f, cfg))(cache, frames)
print(f"encoder prefill: {S_ENC} frames → cross-cache in {time.time()-t0:.2f}s "
      f"(cross_len={int(cache['cross_len'])})")

# ---- greedy decode ---------------------------------------------------------
step = jax.jit(lambda c, t, p: encdec.lm_decode_step(params, c, t, p, cfg))
toks = [bos]
t0 = time.time()
for t in range(12):
    logits, cache = step(cache, toks[-1], jnp.asarray(t, jnp.int32))
    toks.append(jnp.argmax(logits[:, 0:1, : cfg.vocab], axis=-1).astype(jnp.int32))
out = jnp.concatenate(toks, axis=1)
print(f"decoded {out.shape[1]-1} tokens × {B} seqs in {time.time()-t0:.2f}s")
print("sequences:", np.asarray(out)[:2].tolist())

# ---- consistency vs teacher-forced parallel decoder ------------------------
batch = {"frames": frames, "tokens": out[:, :-1]}
full = encdec.lm_forward(params, batch, cfg)
greedy_parallel = jnp.argmax(full[:, -1, : cfg.vocab], axis=-1)
assert bool(jnp.all(greedy_parallel[:, None] == out[:, -1:])), \
    "decode path must match the parallel decoder"
print("decode ≡ teacher-forced parallel: ok")
