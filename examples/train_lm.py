"""NOTE: LM-scale training scaffolding — not part of the DP-LASSO
reproduction (see README "Examples" and docs/API.md for the paper surface).

End-to-end driver (deliverable b): train a ~100M-parameter LM for a few
hundred steps on the synthetic markov stream, with checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

Uses a mid-size llama-style config (not a smoke config): 8 layers, d=512,
vocab 32k ≈ 100M params (counting tied embeddings at init scale).  On CPU
this takes a few minutes; on the production mesh the identical step function
is what launch/dryrun.py lowers at (16,16).
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.loader import ShardedLoader
from repro.data.synthetic import lm_batches
from repro.models.registry import get_model
from repro.train.optimizer import get_optimizer
from repro.train.trainer import TrainConfig, TrainState, fit, make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm_ckpt")
args = ap.parse_args()

# ~100M params: tinyllama family at 8 layers × d_model 512 (overriding the
# full config down to example scale — same code path as the full model).
api = get_model("tinyllama-1.1b", overrides=dict(
    n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, d_ff=2048,
    dtype="float32"))
params = api.init(jax.random.PRNGKey(0))
n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
print(f"model: {n_params / 1e6:.1f}M params")

opt = get_optimizer(api.cfg.optimizer)
state = TrainState(step=jnp.zeros((), jnp.int32), params=params,
                   opt_state=opt.init(params))
tc = TrainConfig(optimizer=api.cfg.optimizer, peak_lr=6e-4,
                 total_steps=args.steps, warmup=20)
ckpt = Checkpointer(args.ckpt_dir, keep=2)
if ckpt.latest_step() is not None:
    state, meta = ckpt.restore(jax.eval_shape(lambda: state))
    print(f"resumed from step {meta['step']}")

# the markov stream uses a 2k-token support (the model keeps its full 32k
# vocab) so a few hundred steps see every bigram several times — enough to
# show real learning rather than memorized noise
stream = ShardedLoader(lm_batches(min(api.cfg.vocab, 2048), args.batch,
                                  args.seq, seed=0))
step_fn = make_train_step(api.loss, tc)
t0 = time.time()
state, history = fit(state, step_fn, stream, steps=args.steps,
                     checkpointer=ckpt, ckpt_every=100,
                     log_every=max(args.steps // 15, 1))
stream.close()
wall = time.time() - t0
first, last = history[0]["loss"], history[-1]["loss"]
print(f"\ntrained {args.steps} steps in {wall:.0f}s "
      f"({args.steps * args.batch * args.seq / wall:.0f} tok/s): "
      f"loss {first:.3f} → {last:.3f}")
assert last < first - 0.5, "the markov structure should be learnable"
print("ok")
