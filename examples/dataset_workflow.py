"""Dataset-store workflow: ingest → split → sweep → evaluate.

    PYTHONPATH=src python examples/dataset_workflow.py

The production data path (DESIGN.md §7) end-to-end:

  1. ``registry.load("rcv1_like")`` materializes the named Table-2 twin
     through the sharded on-disk store on first use (streamed ingestion +
     column stats + content hash) and merely opens it ever after — run the
     script twice to see the warm path;
  2. a deterministic hash split carves train/test rows;
  3. a (λ, ε) grid sweeps the *training* rows via ``solve_many`` (one
     vmapped scan per group);
  4. each fit is scored on the held-out rows — the model-selection loop the
     store amortizes across processes and tenants.
"""
import argparse
import os
import time

import numpy as np

ap = argparse.ArgumentParser()
ap.add_argument("--dataset", default="url_small_like",
                help="a registered name; the URL-style dense informative "
                     "block generalizes to held-out rows at small T")
ap.add_argument("--root", default=None,
                help="store root (default: $REPRO_DATA_DIR or ~/.cache)")
ap.add_argument("--steps", type=int, default=150)
ap.add_argument("--test-frac", type=float, default=0.2)
args = ap.parse_args()
if args.root:
    os.environ["REPRO_DATA_DIR"] = args.root

from repro.core.solvers import FWConfig, grid, solve_many  # noqa: E402
from repro.data import registry  # noqa: E402
from repro.data.store import DatasetRef  # noqa: E402


def accuracy(X, y, w):
    margins = np.asarray(X.matvec(np.asarray(w, np.float64)))
    return float(((margins > 0) == (y > 0.5)).mean())


# ---- 1. ingest (first run) / open (every run after) ------------------------
t0 = time.time()
store = registry.load(args.dataset)
print(f"store {args.dataset}: {store.n}×{store.d}, nnz={store.nnz}, "
      f"{store.n_shards} shards, hash {store.content_hash[:12]}…  "
      f"({time.time() - t0:.2f}s, root={store.root})")

# ---- 2. deterministic hash split -------------------------------------------
train_rows, test_rows = store.split(test_frac=args.test_frac)
print(f"split: {train_rows.size} train / {test_rows.size} test "
      f"(hash-based, stable across processes)")
train_ref = DatasetRef(name=args.dataset, split="train",
                       test_frac=args.test_frac)
X_test, y_test = store.take(test_rows)

# ---- 3. sweep the (λ, ε) grid over the training rows -----------------------
# NOTE on the ε axis: at this toy scale (N ≈ 1.2k, T = 150) the per-step EM
# scale ε'·N/2 only rises above the Gumbel noise floor for large ε — the
# paper's remedy is a huge iteration budget (T up to 400k), which is exactly
# what its cheap iterations make affordable.  The sweep shows the monotone
# utility-in-ε frontier climbing toward the non-private reference.
configs = grid(FWConfig(backend="jax_sparse", steps=args.steps, queue="bsls",
                        delta=1.0 / store.n ** 2),
               lam=(10.0, 30.0), epsilon=(4.0, 16.0, 64.0))
t0 = time.time()
results = solve_many(train_ref, configs=configs)
print(f"\nsolve_many: {len(configs)} configs over the train split "
      f"in {time.time() - t0:.1f}s")

# non-private reference at the same budget: the utility ceiling the DP fits
# approach as ε (or the paper's remedy, the iteration budget T) grows
ref_res = solve_many(train_ref, configs=[
    FWConfig(backend="jax_sparse", steps=args.steps, lam=30.0)])[0]

# ---- 4. evaluate on the held-out rows --------------------------------------
print(f"\n{'λ':>6} {'ε':>5} {'gap_T':>9} {'nnz':>5} {'test acc':>9}")
best = None
for cfg, res in zip(configs, results):
    w = np.asarray(res.w)
    acc = accuracy(X_test, y_test, w)
    best = max(best or (acc, cfg), (acc, cfg), key=lambda t: t[0])
    print(f"{cfg.lam:6.1f} {cfg.epsilon:5.1f} {float(res.gaps[-1]):9.4f} "
          f"{int(res.nnz):5d} {acc:9.3f}")
print(f"{30.0:6.1f} {'∞':>5} {float(ref_res.gaps[-1]):9.4f} "
      f"{int(ref_res.nnz):5d} {accuracy(X_test, y_test, np.asarray(ref_res.w)):9.3f}"
      f"   (non-private reference)")
print(f"\nbest DP fit: λ={best[1].lam:g}, ε={best[1].epsilon:g} "
      f"(test acc {best[0]:.3f}); utility climbs toward the reference as ε "
      f"grows — or, per the paper, as T does at fixed ε")
assert best[0] > 0.55, "expected the large-ε fits to beat chance"
print("ok")
