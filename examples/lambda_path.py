"""Regularization-path demo: a full λ-grid for ~one solve's cost.

    PYTHONPATH=src python examples/lambda_path.py

Model selection over λ is the loop the §14 homotopy path collapses.  This
script ingests a registry twin and solves a strictly decreasing λ-grid two
ways:

  * ``solve_path`` — one warm-started pass: the first λ solves cold, every
    later λ continues from the previous λ's full solver carry at the
    planner's small warm budget, all inside one compiled chunk program, and
    the total ε is split across the grid up-front as **one** DP mechanism;
  * the way ``hyperparam_sweep.py`` would — one independent ``solve`` per λ
    at the full budget, each at ε/√K so the K solves compose to the same
    total ε (advanced composition).

It prints the per-λ table (gap certificate, sparsity, held-out accuracy),
the coefficient path of the strongest coordinates as the L1 ball shrinks,
and the timing comparison.
"""
import argparse
import os
import time

import numpy as np

ap = argparse.ArgumentParser()
ap.add_argument("--dataset", default="rcv1_like")
ap.add_argument("--root", default=None,
                help="store root (default: $REPRO_DATA_DIR or ~/.cache)")
ap.add_argument("--steps", type=int, default=120,
                help="cold budget for the first λ (later λs get the "
                     "planner's warm fraction)")
ap.add_argument("--epsilon", type=float, default=16.0,
                help="total privacy budget of the whole path (see the ε "
                     "note in dataset_workflow.py: twin-scale N needs "
                     "generous ε for the EM signal to clear the noise)")
ap.add_argument("--test-frac", type=float, default=0.2)
args = ap.parse_args()
if args.root:
    os.environ["REPRO_DATA_DIR"] = args.root

from repro.core.solvers import FWConfig, solve, solve_path  # noqa: E402
from repro.data import registry  # noqa: E402
from repro.data.store import DatasetRef  # noqa: E402

LAMBDAS = (40.0, 30.0, 23.0, 17.0, 13.0)


def accuracy(X, y, w):
    margins = np.asarray(X.matvec(np.asarray(w, np.float64)))
    return float(((margins > 0) == (y > 0.5)).mean())


store = registry.load(args.dataset)
print(f"store {args.dataset}: {store.n}×{store.d}, nnz={store.nnz}")
train_rows, test_rows = store.split(test_frac=args.test_frac)
train_ref = DatasetRef(name=args.dataset, split="train",
                       test_frac=args.test_frac)
X_test, y_test = store.take(test_rows)

k_lams = len(LAMBDAS)
base = FWConfig(backend="jax_sparse", queue="bsls", steps=args.steps,
                epsilon=args.epsilon, delta=1.0 / store.n ** 2)

# ---- arm 1: the homotopy path (one warm-started mechanism) -----------------
solve_path(train_ref, config=base, lambdas=LAMBDAS)     # warm-up: compile
t0 = time.time()
path = solve_path(train_ref, config=base, lambdas=LAMBDAS)
t_path = time.time() - t0

# ---- arm 2: independent per-λ solves at the same total ε -------------------
eps_each = args.epsilon / k_lams ** 0.5       # K solves compose to ε total
scratch_cfgs = [FWConfig(backend="jax_sparse", queue="bsls",
                         steps=args.steps, lam=lam, epsilon=eps_each,
                         delta=1.0 / store.n ** 2) for lam in LAMBDAS]
[solve(train_ref, config=c) for c in scratch_cfgs]      # warm-up: compile
t0 = time.time()
scratch = [solve(train_ref, config=c) for c in scratch_cfgs]
t_scratch = time.time() - t0

# ---- per-λ table -----------------------------------------------------------
plan = path.plan
print(f"\n{'λ':>6} {'budget':>7} {'ε_λ':>6} {'gap':>9} {'nnz':>5} "
      f"{'acc(path)':>10} {'acc(scratch)':>13}")
for k, (lam, res) in enumerate(zip(path.lambdas, path)):
    print(f"{lam:6.1f} {plan.budgets[k]:7d} {plan.eps_lambdas[k]:6.2f} "
          f"{float(res.gaps_valid[-1]):9.4f} {int(res.nnz):5d} "
          f"{accuracy(X_test, y_test, np.asarray(res.w)):10.3f} "
          f"{accuracy(X_test, y_test, np.asarray(scratch[k].w)):13.3f}")

# ---- coefficient path: strongest final coords as the ball shrinks ----------
w_final = np.asarray(path.final.w)
top = np.argsort(-np.abs(w_final))[:6]
print("\ncoefficient path (top final coords; L1 ball radius shrinking →)")
header = "  ".join(f"λ={lam:g}".rjust(9) for lam in path.lambdas)
print(f"{'coord':>7} {header}")
for j in top:
    vals = "  ".join(f"{float(np.asarray(r.w)[j]):9.4f}" for r in path)
    print(f"{int(j):7d} {vals}")

# ---- timing ----------------------------------------------------------------
print(f"\npath:    {plan.total_steps:4d} steps in {t_path:6.2f}s "
      f"(one warm-started mechanism, ε = {args.epsilon:g})")
print(f"scratch: {k_lams * args.steps:4d} steps in {t_scratch:6.2f}s "
      f"({k_lams} independent solves à ε/√K ≈ {eps_each:.2f})")
print(f"speedup: {t_scratch / max(t_path, 1e-9):.1f}x at equal total ε "
      f"(benchmarks/bench_path.py gates ≥ 2x on the twins)")
assert len(path) == k_lams and t_path < t_scratch
print("ok")
