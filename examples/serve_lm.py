"""NOTE: LM-scale serving scaffolding — not part of the DP-LASSO
reproduction (see README "Examples" and docs/API.md for the paper surface).

Batched serving example: continuous batching with mixed prompt lengths,
slot reuse and latency stats — plus a greedy-determinism self-check.

    PYTHONPATH=src python examples/serve_lm.py [--arch falcon-mamba-7b]

Works for every decoder arch (GQA / MLA+MoE / mamba state / RG-LRU hybrid) —
the engine auto-detects each cache layout.
"""
import argparse
import time

import jax
import numpy as np

from repro.models.registry import get_model
from repro.serve.engine import Request, ServeConfig, ServingEngine

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="tinyllama-1.1b")
ap.add_argument("--requests", type=int, default=12)
ap.add_argument("--slots", type=int, default=4)
ap.add_argument("--max-new", type=int, default=12)
args = ap.parse_args()

api = get_model(args.arch, smoke=True)
params = api.init(jax.random.PRNGKey(0))
engine = ServingEngine(api, params,
                       ServeConfig(slots=args.slots, max_len=128,
                                   prefill_bucket=32))

rng = np.random.default_rng(0)
t0 = time.time()
for i in range(args.requests):
    plen = int(rng.integers(4, 24))
    engine.submit(Request(uid=i,
                          prompt=rng.integers(1, 100, plen).astype(np.int32),
                          max_new_tokens=args.max_new))
finished = engine.run()
wall = time.time() - t0

gen = sum(len(r.generated) for r in finished)
print(f"served {len(finished)} requests / {gen} tokens in {wall:.1f}s "
      f"({gen / wall:.1f} tok/s, {engine.steps} batched decode steps, "
      f"slot util {gen / max(engine.steps * args.slots, 1):.0%})")

# determinism self-check: resubmitting a prompt reproduces its completion
probe = finished[0]
engine2 = ServingEngine(api, params, ServeConfig(slots=1, max_len=128,
                                                 prefill_bucket=32))
engine2.submit(Request(uid=99, prompt=probe.prompt,
                       max_new_tokens=args.max_new))
redo = engine2.run()[0]
assert redo.generated == probe.generated, "greedy decode must be deterministic"
print("determinism check ok")
