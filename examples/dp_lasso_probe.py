"""DP-LASSO probing of a frozen LM backbone — the paper's technique applied
to the assigned architectures (DESIGN.md §Arch-applicability).

    PYTHONPATH=src python examples/dp_lasso_probe.py [--arch tinyllama-1.1b]

Pipeline: frozen reduced-config backbone → last-token hidden states pushed
through a sparsifying random-ReLU expansion (text-feature-like sparse design
matrix) → (ε, δ)-DP Frank-Wolfe LASSO head on a synthetic downstream label.
The FW optimizer never touches backbone weights (it is a convex linear-model
method — applying it to the transformer itself would void the paper's
sensitivity analysis)."""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.solvers import FWConfig, available_backends, solve
from repro.core.sparse.formats import dense_to_host
from repro.data.synthetic import lm_batches
from repro.models.registry import get_model

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="tinyllama-1.1b")
ap.add_argument("--backend", default="jax_dense", choices=available_backends())
ap.add_argument("--rows", type=int, default=512)
ap.add_argument("--features", type=int, default=4096)
ap.add_argument("--epsilon", type=float, default=1.0)
ap.add_argument("--steps", type=int, default=400)
args = ap.parse_args()

# 1. Frozen backbone features for a batch of sequences.
api = get_model(args.arch, smoke=True)
params = api.init(jax.random.PRNGKey(0))
stream = lm_batches(api.cfg.vocab, args.rows, 32, seed=1)
tokens = jnp.asarray(next(stream)["tokens"])
hidden = api.forward(params, tokens)[:, -1, :]          # (rows, V) logits
hidden = hidden[:, :256].astype(jnp.float32)            # compact summary
print(f"backbone {args.arch}: features {hidden.shape}")

# 2. Sparse random-ReLU expansion → high-dimensional sparse design matrix.
key = jax.random.PRNGKey(2)
proj = jax.random.normal(key, (hidden.shape[1], args.features)) / 16.0
expanded = jax.nn.relu(hidden @ proj)
thresh = jnp.percentile(expanded, 95)                   # keep ~5% of entries
sparse_feats = jnp.where(expanded > thresh, expanded, 0.0)
X = dense_to_host(np.asarray(sparse_feats))
density = X.nnz / (X.shape[0] * X.shape[1])
print(f"design matrix: {X.shape}, density {density:.3%}")

# 3. Synthetic downstream task: planted sparse direction over the features.
rng = np.random.default_rng(3)
w_star = np.zeros(args.features)
w_star[rng.choice(args.features, 32, replace=False)] = rng.normal(0, 2, 32)
margins = X.to_dense() @ w_star
y = (margins > np.median(margins)).astype(np.float64)

# 4. DP Frank-Wolfe LASSO head, through the solver registry.
cfg = FWConfig(backend=args.backend, lam=20.0, steps=args.steps,
               epsilon=args.epsilon, delta=1.0 / args.rows ** 2,
               queue="two_level")
t0 = time.time()
res = solve(X, y, cfg)
w = np.asarray(res.w)
pred = X.to_dense() @ w > 0
acc = (pred == (y > 0.5)).mean()
print(f"DP-LASSO head: acc={acc:.3f} nnz={int((w != 0).sum())} "
      f"ε={args.epsilon} ({time.time() - t0:.1f}s)")
assert acc > 0.55
print("ok")
