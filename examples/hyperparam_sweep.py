"""Batched λ/ε hyperparameter sweep + multi-tenant fit service demo.

    PYTHONPATH=src python examples/hyperparam_sweep.py

Part 1 — the sweep API: a 4λ × 2ε grid of DP-LASSO problems over one sparse
design matrix runs through ``solve_many`` on one shared coercion + setup +
compiled lax.scan of the jax_sparse kernel pipeline — vmapped or re-entered
sequentially, whichever the cost-model planner says is faster here (DESIGN.md
§9) — and prints the paper-style accuracy/sparsity frontier.

Part 2 — the serving API: the same grid arrives as tenant fit requests on a
``FitService``; each tenant's ``PrivacyAccountant`` is charged per request,
an over-budget tenant is refused, and the service reports latency/throughput
(DESIGN.md §6).
"""
import argparse
import time

import numpy as np

from repro.core.dp.accountant import PrivacyAccountant
from repro.core.solvers import FWConfig, grid, solve_many
from repro.data.synthetic import make_sparse_classification
from repro.serve import FitRequest, FitService, FitServiceConfig


def accuracy(X, y, w):
    margins = np.asarray(X.matvec(np.asarray(w, np.float64)))
    return float(((margins > 0) == (y > 0.5)).mean())

ap = argparse.ArgumentParser()
ap.add_argument("--n", type=int, default=400)
ap.add_argument("--d", type=int, default=2000)
ap.add_argument("--steps", type=int, default=80)
args = ap.parse_args()

X, y, _ = make_sparse_classification(
    n=args.n, d=args.d, nnz_per_row=12, informative=24, seed=0)
print(f"design matrix: {X.shape}, nnz/row ≈ {X.nnz / X.shape[0]:.0f}")

# ---- Part 1: one vmapped sweep over the (λ, ε) grid ------------------------
configs = grid(FWConfig(backend="jax_sparse", steps=args.steps, queue="bsls",
                        delta=1.0 / args.n ** 2),
               lam=(5.0, 10.0, 20.0, 40.0), epsilon=(0.5, 2.0))
t0 = time.time()
results = solve_many(X, y, configs)
print(f"\nsolve_many: {len(configs)} configs in {time.time() - t0:.1f}s "
      f"(one coercion + one setup + one compiled scan, scheduled by the "
      f"planner)\n")
print(f"{'λ':>6} {'ε':>5} {'gap_T':>9} {'nnz':>5} {'acc':>6} {'zeros%':>7}")
for cfg, res in zip(configs, results):
    w = np.asarray(res.w)
    zeros_pct = 100.0 * float((w == 0).mean())
    print(f"{cfg.lam:6.1f} {cfg.epsilon:5.1f} {float(res.gaps[-1]):9.4f} "
          f"{int(res.nnz):5d} {accuracy(X, y, w):6.3f} {zeros_pct:7.1f}")

# ---- Part 2: the same traffic through the fit service ----------------------
print("\n--- FitService: two tenants, per-tenant privacy budgets ---")
# accountant δ matches the requests' δ; charges are ε²-equivalent steps, so
# globex (ε=1) can afford its ε=0.5 fits but every ε=2.0 fit is refused
svc = FitService(X, y, accountants={
    "acme": PrivacyAccountant(epsilon=4.0, delta=1.0 / args.n ** 2,
                              total_steps=8 * args.steps),
    "globex": PrivacyAccountant(epsilon=1.0, delta=1.0 / args.n ** 2,
                                total_steps=3 * args.steps),
}, config=FitServiceConfig(slots=4))

uid = 0
for tenant in ("acme", "globex"):
    for cfg in configs[:4]:
        svc.submit(FitRequest(uid=uid, tenant=tenant, config=cfg))
        uid += 1
done = svc.run()
for r in done:
    tail = (f"nnz={int(r.result.nnz)}" if r.status == "done"
            else f"({r.reason})")
    print(f"  req {r.uid:2d} {r.tenant:7s} {r.status:8s} {tail}")
stats = svc.stats()
print(f"throughput: {stats['throughput_fits_per_s']:.2f} fits/s, "
      f"batches: {stats['batch_sizes']}")
for t, s in stats["tenants"].items():
    print(f"  {t}: spent {s['spent_steps']} steps "
          f"(ε ≈ {s['spent_epsilon']:.2f}), {s['remaining_steps']} left")
rejected = [r for r in done if r.status == "rejected"]
assert rejected and all(r.tenant == "globex" for r in rejected)
print("ok")
