"""Quickstart — train a differentially-private LASSO logistic regression on a
sparse high-dimensional dataset with the fast (sub-linear-in-D) Frank-Wolfe.

    PYTHONPATH=src python examples/quickstart.py [--backend jax_dense]

This is the paper's core loop end-to-end through the unified solver registry:
synthetic RCV1-like data → DP-FW with the two-level (Big-Step-Little-Step)
exponential-mechanism sampler → accuracy + privacy report.  Swap engines by
changing ``--backend`` (see ``repro.core.solvers.available_backends()``):
``jax_dense`` is the pure-jnp device scan, ``jax_sparse`` routes the same
iteration through the Pallas kernels, ``host_sparse`` is the faithful host
loop with FLOP audit, ``dense`` the Algorithm-1 baseline.
"""
import argparse
import time

import numpy as np

from repro.core.dp.accountant import PrivacyAccountant
from repro.core.solvers import FWConfig, available_backends, solve
from repro.core.sparse.formats import host_to_padded
from repro.data.synthetic import make_sparse_classification

ap = argparse.ArgumentParser()
ap.add_argument("--backend", default="jax_dense", choices=available_backends())
ap.add_argument("--steps", type=int, default=1_000)
ap.add_argument("--gap-tol", type=float, default=0.0,
                help="stop once the FW duality-gap certificate falls to "
                     "this value (0 = run all T steps); see FWResult."
                     "stop_step/stop_reason")
args = ap.parse_args()

# 1. A sparse dataset: 2 000 rows, 8 000 features, ~40 nnz/row.
X, y, w_true = make_sparse_classification(
    n=2_000, d=8_000, nnz_per_row=40, informative=64, seed=0)
pcsr, pcsc = host_to_padded(X)
print(f"dataset: N={X.shape[0]} D={X.shape[1]} nnz={X.nnz} "
      f"(padding waste {pcsr.padding_overhead:.1f}x)")

# 2. (ε, δ)-DP Frank-Wolfe, T iterations, via the solver registry.  The
#    'two_level' queue is the DP exponential mechanism (paper Alg 4); the
#    registry maps it onto each backend's native realization.
epsilon, delta = 1.0, 1.0 / X.shape[0] ** 2
cfg = FWConfig(backend=args.backend, lam=30.0, steps=args.steps,
               epsilon=epsilon, delta=delta, queue="two_level", seed=0,
               gap_tol=args.gap_tol)
t0 = time.time()
result = solve((pcsr, pcsc) if args.backend.startswith("jax") else X, y, cfg)
w = np.asarray(result.w)
stop = result.stop_step_or(args.steps)
print(f"[{args.backend}] trained in {time.time() - t0:.1f}s; "
      f"stopped at step {stop}/{args.steps} ({result.stop_reason}); "
      f"final FW gap {float(result.gaps[stop - 1]):.4f}")

# 3. Evaluate + account.
margins = np.asarray(pcsr.matvec(np.asarray(w, np.float32)))
acc = ((margins > 0) == (y > 0.5)).mean()
acct = PrivacyAccountant(epsilon=epsilon, delta=delta, total_steps=args.steps)
acct.spend(args.steps)
print(f"accuracy {acc:.3f} | nnz(w) = {(w != 0).sum()} of {len(w)} "
      f"| spent ε = {acct.spent_epsilon():.2f} (δ = {delta:.1e})")
# (an aggressive --gap-tol can legitimately stop long before the accuracy
# budget is spent; only hold the bar when the full budget ran)
if result.stop_reason == "max_steps":
    assert acc > 0.6, "quickstart should beat chance comfortably"
print("ok")
