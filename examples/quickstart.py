"""Quickstart — train a differentially-private LASSO logistic regression on a
sparse high-dimensional dataset with the fast (sub-linear-in-D) Frank-Wolfe.

    PYTHONPATH=src python examples/quickstart.py

This is the paper's core loop end-to-end: synthetic RCV1-like data → padded
sparse layouts → DP-FW with the two-level (Big-Step-Little-Step) exponential-
mechanism sampler → accuracy + privacy report.
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core.dp.accountant import PrivacyAccountant
from repro.core.fw_jax import SparseJaxConfig, sparse_fw_jax
from repro.core.sparse.formats import host_to_padded
from repro.data.synthetic import make_sparse_classification

# 1. A sparse dataset: 2 000 rows, 8 000 features, ~40 nnz/row.
X, y, w_true = make_sparse_classification(
    n=2_000, d=8_000, nnz_per_row=40, informative=64, seed=0)
pcsr, pcsc = host_to_padded(X)
print(f"dataset: N={X.shape[0]} D={X.shape[1]} nnz={X.nnz} "
      f"(padding waste {pcsr.padding_overhead:.1f}x)")

# 2. (ε, δ)-DP Frank-Wolfe, T = 1 000 iterations inside one lax.scan.
epsilon, delta, steps = 1.0, 1.0 / X.shape[0] ** 2, 1_000
cfg = SparseJaxConfig(lam=30.0, steps=steps, epsilon=epsilon, delta=delta,
                      queue="two_level", seed=0)
t0 = time.time()
result = sparse_fw_jax(pcsr, pcsc, jnp.asarray(y, jnp.float32), cfg)
w = np.asarray(result.w)
print(f"trained in {time.time() - t0:.1f}s; final FW gap {float(result.gaps[-1]):.4f}")

# 3. Evaluate + account.
margins = np.asarray(pcsr.matvec(jnp.asarray(w)))
acc = ((margins > 0) == (y > 0.5)).mean()
acct = PrivacyAccountant(epsilon=epsilon, delta=delta, total_steps=steps)
acct.spend(steps)
print(f"accuracy {acc:.3f} | nnz(w) = {(w != 0).sum()} of {len(w)} "
      f"| spent ε = {acct.spent_epsilon():.2f} (δ = {delta:.1e})")
assert acc > 0.6, "quickstart should beat chance comfortably"
print("ok")
